"""Asyncio master: admission, dispatch, heartbeats, checkpointing.

Single-threaded by construction: every mutation of the engine happens
on the event loop (message handlers and the pacer), so the journaled
stimulus order is a total order — the property the twin replay depends
on.  The master's responsibilities around the engine:

* **clients** — line-JSON request/reply (see protocol.py): submit with
  idempotency tags, job state queries, telemetry pull/stream,
  checkpoint, graceful shutdown;
* **admission** — token-bucket rate limits and max-live-jobs
  backpressure (admission.py); queued jobs drain on completions and
  pacer ticks;
* **workers** — registration, heartbeat deadlines (a silent worker
  becomes a journaled scripted ``crash``; a re-registration becomes
  ``recover``), and advisory dispatch: every Start/Resume/Suspend/Kill
  the engine applies is mirrored to the worker owning that machine;
* **checkpointing** — the journal already *is* the scheduler+estimator
  checkpoint (log-structured; replay reconstructs state
  bit-identically).  The periodic checkpoint file only snapshots what
  the journal cannot know: submissions still queued in admission
  control.  Restore = repair journal, replay, requeue, resume clock.
"""

from __future__ import annotations

import asyncio
import json
import time
from dataclasses import dataclass, field
from pathlib import Path

from repro.core.scheduler import Kill, Resume, Start, Suspend
from repro.service import protocol
from repro.service.admission import AdmissionConfig, AdmissionControl
from repro.service.engine import LiveEngine
from repro.service.journal import read_journal
from repro.service.telemetry import Telemetry

CHECKPOINT_KIND = "repro-service-checkpoint"


@dataclass
class MasterConfig:
    host: str = "127.0.0.1"
    port: int = 0  # 0 = ephemeral; resolved port in Master.port
    #: Pacer period (wall seconds): advance cadence and heartbeat check.
    pace_wall: float = 0.02
    #: Wall seconds of heartbeat silence after which a worker is dead.
    worker_dead_wall: float = 0.5
    checkpoint_path: str | None = None
    checkpoint_every_wall: float = 0.25
    #: Re-run the auto-epsilon controller this often (0 = never).  On
    #: by default: the service's batching window tracks observed
    #: arrival burstiness (auto_event_epsilon), with every retune
    #: journaled so the twin replays it.
    eps_auto_every_wall: float = 0.25
    admission: AdmissionConfig = field(default_factory=AdmissionConfig)


@dataclass
class _Worker:
    machine: int
    queue: asyncio.Queue
    alive: bool = True
    last_hb: float = 0.0
    sender: asyncio.Task | None = None


class Master:
    def __init__(self, engine: LiveEngine, cfg: MasterConfig | None = None):
        self.engine = engine
        self.cfg = cfg or MasterConfig()
        self.admission = AdmissionControl(self.cfg.admission)
        self.telemetry = Telemetry(engine)
        self.workers: dict[int, _Worker] = {}
        #: tag -> job_id (admitted) or "queued" (held in admission).
        self.tags: dict[str, object] = {}
        self._waiters: dict[int, list[asyncio.Future]] = {}
        self._server: asyncio.AbstractServer | None = None
        self._pacer: asyncio.Task | None = None
        self._stopping = asyncio.Event()
        self.port: int | None = None
        engine.sim.action_listener = self._on_action
        engine.sim.completion_listener = self._on_completion
        self._seed_from_journal()

    # -- restore glue ----------------------------------------------------
    def _seed_from_journal(self) -> None:
        """Rebuild the tag-dedup map and telemetry sizes from the
        journal (restore path; a fresh journal has no job lines)."""
        _, entries = read_journal(self.engine.journal.path)
        from repro.scenarios.trace import job_from_record

        for d in entries:
            if d.get("event") is not None:
                continue
            spec = job_from_record(d)
            self.telemetry.note_job(spec)
            if "tag" in d:
                self.tags[d["tag"]] = spec.job_id
        # telemetry "submitted" must match the engine's journal count.
        self.telemetry.counters["submitted"] = self.engine.submitted

    def load_checkpoint(self) -> None:
        """Requeue admission state from the checkpoint file (if any).
        Tags already admitted per the journal win over the checkpoint's
        queue snapshot — a job must never be admitted twice."""
        path = self.cfg.checkpoint_path
        if not path or not Path(path).exists():
            return
        ck = json.loads(Path(path).read_text())
        if ck.get("kind") != CHECKPOINT_KIND:
            raise ValueError(f"{path}: not a {CHECKPOINT_KIND} file")
        queued: dict[str, list] = {}
        for user, items in ck.get("queued", {}).items():
            keep = []
            for item in items:
                tag = item.get("tag")
                if tag is not None and tag in self.tags:
                    continue  # journal says it was admitted before the crash
                if tag is not None:
                    self.tags[tag] = "queued"
                keep.append(item)
            if keep:
                queued[user] = keep
        self.admission.requeue(queued)

    def checkpoint(self) -> None:
        path = self.cfg.checkpoint_path
        if not path:
            return
        ck = {
            "kind": CHECKPOINT_KIND,
            "version": 1,
            "v_now": self.engine.virtual_now(),
            "journal": str(self.engine.journal.path),
            "queued": self.admission.queued_items(),
        }
        tmp = Path(path).with_suffix(".tmp")
        tmp.write_text(json.dumps(ck, sort_keys=True))
        tmp.replace(path)  # atomic: a crash mid-write never corrupts

    # -- engine listeners (called synchronously inside sim.run) ----------
    def _on_action(self, action, now: float) -> None:
        if isinstance(action, (Start, Resume)):
            machine = action.slot.machine
            att = action.attempt
            rem = att.remaining
            if att.rate != 1.0:
                rem = rem / att.rate
            msg = {
                "op": "launch",
                "key": list(att.spec.key),
                "machine": machine,
                "wall_s": rem / self.engine.time_scale,
            }
        elif isinstance(action, (Suspend, Kill)):
            att = action.attempt
            machine = att.machine
            msg = {
                "op": "suspend" if isinstance(action, Suspend) else "kill",
                "key": list(att.spec.key),
            }
        else:  # pragma: no cover - future action kinds are advisory too
            return
        w = self.workers.get(machine)
        if w is not None and w.alive:
            w.queue.put_nowait(msg)

    def _on_completion(self, job_id: int, now: float) -> None:
        for fut in self._waiters.pop(job_id, ()):
            if not fut.done():
                fut.set_result(now)

    # -- lifecycle -------------------------------------------------------
    async def start(self) -> None:
        self.load_checkpoint()
        self._server = await asyncio.start_server(
            self._handle, self.cfg.host, self.cfg.port
        )
        self.port = self._server.sockets[0].getsockname()[1]
        self._pacer = asyncio.ensure_future(self._pace())

    async def serve_forever(self) -> None:
        await self._stopping.wait()
        await self.stop()

    async def stop(self) -> None:
        self._stopping.set()
        if self._pacer is not None:
            self._pacer.cancel()
            try:
                await self._pacer
            except asyncio.CancelledError:
                pass
        for w in self.workers.values():
            if w.sender is not None:
                w.sender.cancel()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.checkpoint()
        self.engine.journal.close()

    # -- pacer -----------------------------------------------------------
    async def _pace(self) -> None:
        cfg = self.cfg
        last_eps = last_ck = time.monotonic()
        while True:
            await asyncio.sleep(cfg.pace_wall)
            wall = time.monotonic()
            self.engine.advance()
            self.engine.sim.scheduler.on_wall_tick(wall, self.engine.sim._now)
            self._check_worker_deadlines(wall)
            self._drain_admission()
            if (
                cfg.eps_auto_every_wall > 0
                and wall - last_eps >= cfg.eps_auto_every_wall
            ):
                last_eps = wall
                self.engine.retune_epsilon()
            if (
                cfg.checkpoint_path
                and wall - last_ck >= cfg.checkpoint_every_wall
            ):
                last_ck = wall
                self.checkpoint()

    def _check_worker_deadlines(self, wall: float) -> None:
        for w in self.workers.values():
            if w.alive and wall - w.last_hb > self.cfg.worker_dead_wall:
                w.alive = False
                self.telemetry.counters["worker_crashes"] += 1
                self.engine.inject("crash", w.machine)

    def _drain_admission(self) -> None:
        for user, item in self.admission.drain(self.engine.live_jobs()):
            self._admit(user, item)

    def _admit(self, user: str, item: dict) -> int:
        spec = self.engine.submit(
            item["job"], user=user, tag=item.get("tag")
        )
        self.telemetry.note_job(spec)
        if item.get("tag") is not None:
            self.tags[item["tag"]] = spec.job_id
        return spec.job_id

    # -- connections -----------------------------------------------------
    async def _handle(self, reader, writer) -> None:
        try:
            while True:
                msg = await protocol.recv(reader)
                if msg is None:
                    return
                op = msg.get("op")
                if op == "register":
                    await self._worker_loop(msg, reader, writer)
                    return
                reply = await self._dispatch(op, msg, writer)
                if reply is not None:
                    await protocol.send(writer, reply)
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            writer.close()

    async def _dispatch(self, op: str, msg: dict, writer) -> dict | None:
        if op == "submit":
            return self._op_submit(msg)
        if op == "job":
            return self._op_job(msg)
        if op == "status":
            return {"ok": True, **self.telemetry.snapshot(
                workers=self._worker_block())}
        if op == "telemetry":
            ticks = int(msg.get("ticks", 1))
            interval = float(msg.get("interval", 0.1))
            for i in range(ticks):
                if i:
                    await asyncio.sleep(interval)
                await protocol.send(
                    writer,
                    {"ok": True, "tick": i, **self.telemetry.snapshot(
                        workers=self._worker_block())},
                )
            return None
        if op == "wait":
            return await self._op_wait(msg)
        if op == "checkpoint":
            self.checkpoint()
            return {"ok": True}
        if op == "shutdown":
            self._stopping.set()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def _op_submit(self, msg: dict) -> dict:
        user = str(msg.get("user", "anonymous"))
        tag = msg.get("tag")
        if tag is not None and tag in self.tags:
            self.telemetry.counters["deduped"] += 1
            known = self.tags[tag]
            if known == "queued":
                return {"ok": True, "decision": "queued", "job_id": None}
            return {"ok": True, "decision": "dedup", "job_id": known}
        item = {"job": msg.get("job", {}), "tag": tag}
        verdict = self.admission.offer(
            user, item, time.monotonic(), self.engine.live_jobs()
        )
        if verdict == "admit":
            return {
                "ok": True,
                "decision": "admit",
                "job_id": self._admit(user, item),
            }
        if verdict == "queued":
            self.telemetry.counters["queued"] += 1
            if tag is not None:
                self.tags[tag] = "queued"
            return {"ok": True, "decision": "queued", "job_id": None}
        self.telemetry.counters["rejected"] += 1
        return {"ok": False, "error": verdict}

    def _op_job(self, msg: dict) -> dict:
        jid = msg.get("job_id")
        res = self.engine.sim.result
        if jid in res.completion:
            return {"ok": True, "state": "done",
                    "completion_t": res.completion[jid]}
        if jid is not None and jid < self.engine.next_job_id:
            return {"ok": True, "state": "live"}
        return {"ok": False, "error": f"unknown job {jid!r}"}

    async def _op_wait(self, msg: dict) -> dict:
        jid = int(msg.get("job_id", -1))
        res = self.engine.sim.result
        if jid in res.completion:
            return {"ok": True, "state": "done",
                    "completion_t": res.completion[jid]}
        if jid >= self.engine.next_job_id:
            return {"ok": False, "error": f"unknown job {jid}"}
        fut = asyncio.get_running_loop().create_future()
        self._waiters.setdefault(jid, []).append(fut)
        try:
            t = await asyncio.wait_for(fut, float(msg.get("timeout", 30.0)))
        except asyncio.TimeoutError:
            return {"ok": False, "error": "timeout"}
        return {"ok": True, "state": "done", "completion_t": t}

    # -- worker handling -------------------------------------------------
    def _worker_block(self) -> dict:
        return {
            str(m): {"alive": w.alive}
            for m, w in sorted(self.workers.items())
        }

    async def _worker_loop(self, register: dict, reader, writer) -> None:
        machine = int(register["machine"])
        if not 0 <= machine < self.engine.sim.spec.num_machines:
            await protocol.send(
                writer, {"ok": False, "error": f"unknown machine {machine}"}
            )
            return
        now = time.monotonic()
        prior = self.workers.get(machine)
        if prior is not None:
            if prior.sender is not None:
                prior.sender.cancel()
            if not prior.alive:
                # Rejoin after a declared death: journaled recover, and
                # the fault layer's readmission machinery takes it back.
                self.telemetry.counters["worker_rejoins"] += 1
                self.engine.inject("recover", machine)
        w = _Worker(machine=machine, queue=asyncio.Queue(), last_hb=now)
        w.sender = asyncio.ensure_future(self._worker_sender(w, writer))
        self.workers[machine] = w
        while True:
            msg = await protocol.recv(reader)
            if msg is None:
                break  # silence -> the deadline check declares the crash
            if msg.get("op") == "heartbeat":
                w.last_hb = time.monotonic()
            # task_done is advisory: engine completions are authoritative.

    async def _worker_sender(self, w: _Worker, writer) -> None:
        try:
            while True:
                msg = await w.queue.get()
                await protocol.send(writer, msg)
        except (asyncio.CancelledError, ConnectionError):
            pass


async def run_master(
    engine: LiveEngine,
    cfg: MasterConfig,
    *,
    ready_cb=None,
) -> Master:
    """Start a master and serve until shutdown; returns the master."""
    master = Master(engine, cfg)
    await master.start()
    if ready_cb is not None:
        ready_cb(master)
    await master.serve_forever()
    return master
