"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel residual blocks
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    act="silu_glu",
    norm="layernorm",
    use_bias=False,
    parallel_residual=True,     # Cohere's parallel attn+FFN blocks
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)

SMOKE = reduced(CONFIG)
