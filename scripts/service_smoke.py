#!/usr/bin/env python
"""Live-service smoke: master + 2 in-process workers + a 50-job burst,
gated on the twin property and decision latency.

Boots a real asyncio master (admission control on, checkpointing on),
connects two worker agents, fires a 50-job burst from 4 users, kills
one worker mid-workload (exercising the journaled crash path), waits
for the engine to drain, then:

* replays the journal through the offline Simulator and **fails** if
  the twin's completion fingerprint differs from the live run's;
* **fails** if p99 decision latency (wall ms per work-doing engine
  advance) exceeds ``--p99-ms`` (default 250 ms — generous; the quick
  cells run well under 10 ms, the bound exists to catch pathological
  O(n) blowups in the live path, not to benchmark the host).

Exit 0 = both gates hold.  Run by scripts/check.sh as the service
smoke stage; standalone:

    PYTHONPATH=src python scripts/service_smoke.py --jobs 50
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
import tempfile
import time
from pathlib import Path

from repro.core.types import ClusterSpec
from repro.service import (
    AdmissionConfig,
    LiveEngine,
    Master,
    MasterConfig,
    WorkerAgent,
    live_fingerprint,
    replay_journal,
)
from repro.service.protocol import ServiceClient

TIME_SCALE = 1000.0


def mk_job(i: int) -> dict:
    return {
        "name": f"smoke-{i}",
        "map": [[30.0 + 9.0 * ((i + k) % 7), [], 0]
                for k in range(2 + i % 4)],
        "reduce": [[20.0, [], 0]] if i % 3 else [],
        "weight": 1.0,
        "reduce_slowstart": 1.0,
    }


async def run(args, tmp: Path) -> dict:
    journal = tmp / "smoke.jsonl"
    engine = LiveEngine.create(
        journal,
        args.policy,
        ClusterSpec(
            num_machines=2, map_slots_per_machine=2,
            reduce_slots_per_machine=1,
        ),
        time_scale=TIME_SCALE,
    )
    master = Master(engine, MasterConfig(
        pace_wall=0.005,
        worker_dead_wall=0.15,
        checkpoint_path=str(tmp / "smoke-ck.json"),
        admission=AdmissionConfig(max_live_jobs=32),
    ))
    await master.start()
    workers = []
    for m in range(2):
        w = WorkerAgent("127.0.0.1", master.port, m, heartbeat_wall=0.03)
        await w.start()
        workers.append(w)

    loop = asyncio.get_running_loop()

    def burst():
        with ServiceClient("127.0.0.1", master.port) as c:
            for i in range(args.jobs):
                r = c.call({
                    "op": "submit", "user": f"user-{i % 4}",
                    "tag": f"smoke-{i}", "job": mk_job(i),
                })
                assert r["ok"], r

    await loop.run_in_executor(None, burst)

    # Kill one worker mid-workload: the master journals the crash and
    # the fault machinery reschedules its tasks.
    while len(engine.sim.result.completion) < args.jobs // 10:
        await asyncio.sleep(0.01)
    await workers[1].die()

    t0 = time.monotonic()
    while len(engine.sim.result.completion) < args.jobs:
        if time.monotonic() - t0 > args.timeout:
            raise SystemExit(
                f"smoke timed out: "
                f"{len(engine.sim.result.completion)}/{args.jobs} done"
            )
        await asyncio.sleep(0.02)

    def status():
        with ServiceClient("127.0.0.1", master.port) as c:
            return c.call({"op": "status"})

    snap = await loop.run_in_executor(None, status)
    fp_live = live_fingerprint(engine.sim)
    await master.stop()
    for w in workers:
        await w.die()
    return {"snap": snap, "fp_live": fp_live, "journal": journal}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=50)
    ap.add_argument("--policy", default="hfsp")
    ap.add_argument("--p99-ms", type=float, default=250.0)
    ap.add_argument("--timeout", type=float, default=60.0)
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="service-smoke-") as d:
        out = asyncio.run(run(args, Path(d)))
        twin = replay_journal(out["journal"])
        fp_twin = live_fingerprint(twin)

    snap = out["snap"]
    lat = snap["decision_latency_ms"]
    crashes = snap["jobs"]["worker_crashes"]
    print(json.dumps({
        "jobs_completed": snap["jobs"]["completed"],
        "worker_crashes": crashes,
        "fingerprint_live": out["fp_live"],
        "fingerprint_twin": fp_twin,
        "decision_latency_ms": {
            k: round(lat[k], 3) for k in ("p50", "p95", "p99")
            if k in lat
        },
        "goodput": round(snap["goodput"], 4),
        "jain_slowdown": round(snap["fairness"]["jain_slowdown"], 4),
    }, indent=2, sort_keys=True))

    ok = True
    if fp_twin != out["fp_live"]:
        print("FAIL: twin replay fingerprint differs from live run",
              file=sys.stderr)
        ok = False
    if crashes < 1:
        print("FAIL: worker death was never declared", file=sys.stderr)
        ok = False
    if lat.get("p99", 0.0) > args.p99_ms:
        print(
            f"FAIL: p99 decision latency {lat['p99']:.1f}ms > "
            f"{args.p99_ms}ms", file=sys.stderr,
        )
        ok = False
    if ok:
        print("service smoke OK: live == twin, "
              f"p99 decision latency {lat.get('p99', 0.0):.2f}ms")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
