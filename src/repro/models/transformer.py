"""The unified causal LM covering the assigned decoder-only families:

* ``dense``  — olmo-1b, command-r-35b (parallel residual), gemma2-2b
  (local/global alternation + softcaps), starcoder2-3b (biases, plain MLP);
* ``vlm``    — llava-next-34b (patch-embedding stub prepended to tokens);
* ``moe``    — granite-moe (40e top-8), llama4-scout (16e top-1 + shared);
* ``ssm``    — rwkv6 (attention-free);
* ``hybrid`` — zamba2 (mamba2 stack with a *shared* attention block every
  ``shared_attn_period`` layers).

Two stack execution modes, selected by ``cfg.scan_layers``:

* True (default): one ``jax.lax.scan`` over stacked parameters — a single
  compiled body regardless of depth, which keeps 512-device dry-run
  compiles tractable.  Heterogeneity (local vs global attention) is a
  scanned per-layer flag, not a separate body.
* False: an unrolled python loop — used by the dry-run's roofline cost
  extrapolation (XLA's cost_analysis counts while-loop bodies once, so
  costs are measured at small unrolled depths and extrapolated linearly).

Functional API:
    params = init_lm(cfg, rng)
    logits, aux = lm_forward(cfg, params, batch)                (train/prefill)
    cache = init_lm_cache(cfg, batch, max_seq)
    logits, cache = lm_decode(cfg, params, tokens, positions, cache)
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rwkv as R
from repro.models import ssd as M
from repro.models.attention import (
    attention_block,
    decode_attention_block,
    init_attention,
    init_kv_cache,
)
from repro.models.common import (
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
    unembed,
)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Layer-kind helpers
# ---------------------------------------------------------------------------
def global_flags(cfg: ModelConfig) -> np.ndarray:
    """(L,) bool — which layers use full (global) attention.  gemma2
    alternates local/global with the *global* layer every Nth."""
    L = cfg.num_layers
    if cfg.local_global_period:
        idx = np.arange(L)
        return (idx % cfg.local_global_period) == (cfg.local_global_period - 1)
    return np.ones((L,), dtype=bool)


def _n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_period if cfg.shared_attn_period else 0


def _layer_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_lm(cfg: ModelConfig, key) -> dict:
    ke, kb = jax.random.split(key, 2)
    L = cfg.num_layers
    p: dict = {"embed": init_embed(cfg, ke), "final_norm": init_norm(cfg)}

    if cfg.family in ("dense", "vlm", "moe"):
        ka, km = jax.random.split(kb, 2)
        p["layers"] = {
            "ln1": _stack_norms(cfg, L),
            "attn": init_attention(cfg, ka, layers=L),
            "ln2": _stack_norms(cfg, L),
        }
        if cfg.post_block_norm:
            p["layers"]["post_ln1"] = _stack_norms(cfg, L)
            p["layers"]["post_ln2"] = _stack_norms(cfg, L)
        if cfg.family == "moe":
            p["layers"]["moe"] = init_moe(cfg, km, layers=L)
        else:
            p["layers"]["mlp"] = init_mlp(cfg, km, layers=L)
    elif cfg.family == "ssm":  # rwkv6
        k1, k2 = jax.random.split(kb)
        p["layers"] = {
            "ln1": _stack_norms(cfg, L),
            "tmix": R.init_rwkv_block(cfg, k1, layers=L),
            "ln2": _stack_norms(cfg, L),
            "cmix": R.init_channel_mix(cfg, k2, layers=L),
        }
    elif cfg.family == "hybrid":  # zamba2
        k1, k2, k3 = jax.random.split(kb, 3)
        p["layers"] = {
            "ln1": _stack_norms(cfg, L),
            "ssd": M.init_ssd_block(cfg, k1, layers=L),
        }
        # ONE shared attention+MLP block reused every shared_attn_period
        # layers (zamba2's parameter-sharing trick).
        p["shared"] = {
            "ln1": init_norm(cfg),
            "attn": init_attention(cfg, k2),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(cfg, k3),
        }
    else:
        raise ValueError(f"init_lm does not handle family={cfg.family}")
    return p


def _stack_norms(cfg: ModelConfig, L: int) -> dict:
    base = init_norm(cfg)
    return {k: jnp.broadcast_to(v, (L, *v.shape)).copy() for k, v in base.items()}


# ---------------------------------------------------------------------------
# Per-layer block bodies (shared by the scan and unrolled paths)
# ---------------------------------------------------------------------------
def _attn_layer(cfg, p_l, x, aux, positions, is_global, *, use_flash, interpret):
    h = apply_norm(cfg, p_l["ln1"], x)
    h = attention_block(
        cfg, p_l["attn"], h, positions, is_global,
        use_flash=use_flash, interpret=interpret,
    )
    if cfg.post_block_norm:
        h = apply_norm(cfg, p_l["post_ln1"], h)
    if cfg.parallel_residual:
        # command-r: attn and MLP read the same normed input.
        m = apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln1"], x))
        return x + h + m, aux
    x = x + h
    h2 = apply_norm(cfg, p_l["ln2"], x)
    if cfg.family == "moe":
        m, a = moe_ffn(cfg, p_l["moe"], h2)
        aux = aux + a
    else:
        m = apply_mlp(cfg, p_l["mlp"], h2)
    if cfg.post_block_norm:
        m = apply_norm(cfg, p_l["post_ln2"], m)
    return x + m, aux


def _rwkv_layer(cfg, p_l, s_l, x, *, interpret):
    h, st = R.rwkv_time_mix(
        cfg, p_l["tmix"], apply_norm(cfg, p_l["ln1"], x),
        {"S": s_l["S"], "shift": s_l["shift"]}, interpret=interpret,
    )
    x = x + h
    h2, cshift = R.rwkv_channel_mix(
        cfg, p_l["cmix"], apply_norm(cfg, p_l["ln2"], x), s_l["cmix_shift"]
    )
    new_state = {"S": st["S"], "shift": st["shift"], "cmix_shift": cshift}
    return x + h2, new_state


def _shared_attn_block(cfg, shared, x, positions, *, use_flash, interpret):
    h = apply_norm(cfg, shared["ln1"], x)
    h = attention_block(
        cfg, shared["attn"], h, positions,
        use_flash=use_flash, interpret=interpret,
    )
    x = x + h
    m = apply_mlp(cfg, shared["mlp"], apply_norm(cfg, shared["ln2"], x))
    return x + m


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def lm_forward(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    use_flash: bool = False,
    interpret: bool = False,
    unembed_last_only: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {"tokens": (b, s)[, "patch_embeds": (b, P, d)]}.
    Returns (logits (b, s_total, V), aux_loss scalar)."""
    x = embed_tokens(cfg, params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    kw = dict(use_flash=use_flash, interpret=interpret)

    if cfg.family in ("dense", "vlm", "moe"):
        x, aux = _attn_stack_forward(cfg, params, x, positions, **kw)
    elif cfg.family == "ssm":
        x, aux = _rwkv_stack_forward(cfg, params, x, interpret=interpret)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_stack_forward(cfg, params, x, positions, **kw)
    else:
        raise ValueError(cfg.family)

    if unembed_last_only:
        x = x[:, -1:, :]
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, aux


def _attn_stack_forward(cfg, params, x, positions, **kw):
    flags = global_flags(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def layer(p_l, x, aux, positions, is_global):
        return _attn_layer(cfg, p_l, x, aux, positions, is_global, **kw)

    if cfg.remat:
        layer = jax.checkpoint(layer)

    if not cfg.scan_layers:
        aux = aux0
        for i in range(cfg.num_layers):
            x, aux = layer(
                _layer_slice(params["layers"], i), x, aux,
                positions, bool(flags[i]),
            )
        return x, aux

    def body(carry, xs):
        x, aux = carry
        p_l, is_global = xs
        x, aux = layer(p_l, x, aux, positions, is_global)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        body, (x, aux0), (params["layers"], jnp.asarray(flags))
    )
    return x, aux


def _rwkv_stack_forward(cfg, params, x, *, interpret):
    b = x.shape[0]
    state = R.init_rwkv_state(cfg, b, layers=cfg.num_layers)

    def layer(p_l, s_l, x):
        return _rwkv_layer(cfg, p_l, s_l, x, interpret=interpret)

    if cfg.remat:
        layer = jax.checkpoint(layer)

    if not cfg.scan_layers:
        for i in range(cfg.num_layers):
            x, _ = layer(
                _layer_slice(params["layers"], i), _layer_slice(state, i), x
            )
        return x, jnp.zeros((), jnp.float32)

    def body(x, xs):
        p_l, s_l = xs
        x, _ = layer(p_l, s_l, x)
        return x, None

    x, _ = jax.lax.scan(body, x, (params["layers"], state))
    return x, jnp.zeros((), jnp.float32)


def _hybrid_stack_forward(cfg, params, x, positions, **kw):
    b = x.shape[0]
    state = M.init_ssd_state(cfg, b, layers=cfg.num_layers)
    period = cfg.shared_attn_period
    shared = params["shared"]
    interpret = kw.get("interpret", False)

    def mamba_layer(p_l, s_l, x):
        h, _ = M.ssd_block(
            cfg, p_l["ssd"], apply_norm(cfg, p_l["ln1"], x), s_l,
            interpret=interpret,
        )
        return x + h

    def shared_layer(x, positions):
        return _shared_attn_block(cfg, shared, x, positions, **kw)

    if cfg.remat:
        mamba_layer = jax.checkpoint(mamba_layer)
        shared_layer = jax.checkpoint(shared_layer)

    if not cfg.scan_layers:
        for i in range(cfg.num_layers):
            x = mamba_layer(
                _layer_slice(params["layers"], i), _layer_slice(state, i), x
            )
            if period and (i + 1) % period == 0:
                x = shared_layer(x, positions)
        return x, jnp.zeros((), jnp.float32)

    def body(carry, xs):
        x, idx = carry
        p_l, s_l = xs
        x = mamba_layer(p_l, s_l, x)
        if period:
            x = jax.lax.cond(
                (idx + 1) % period == 0,
                lambda v: shared_layer(v, positions),
                lambda v: v,
                x,
            )
        return (x, idx + 1), None

    (x, _), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.int32)), (params["layers"], state)
    )
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode (one token against a cache)
# ---------------------------------------------------------------------------
def init_lm_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    if cfg.family in ("dense", "vlm", "moe"):
        return init_kv_cache(cfg, batch, max_seq, layers=cfg.num_layers)
    if cfg.family == "ssm":
        return R.init_rwkv_state(cfg, batch, layers=cfg.num_layers)
    if cfg.family == "hybrid":
        cache = M.init_ssd_state(cfg, batch, layers=cfg.num_layers)
        napp = _n_shared_applications(cfg)
        kv = init_kv_cache(cfg, batch, max_seq, layers=napp)
        cache["shared_k"] = kv["k"]
        cache["shared_v"] = kv["v"]
        return cache
    raise ValueError(cfg.family)


def _decode_attn_layer(cfg, p_l, x, positions, k_l, v_l, is_global):
    h = apply_norm(cfg, p_l["ln1"], x)
    h, k_l, v_l = decode_attention_block(
        cfg, p_l["attn"], h, positions, k_l, v_l, is_global
    )
    if cfg.post_block_norm:
        h = apply_norm(cfg, p_l["post_ln1"], h)
    if cfg.parallel_residual:
        m = apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln1"], x))
        return x + h + m, k_l, v_l
    x = x + h
    h2 = apply_norm(cfg, p_l["ln2"], x)
    if cfg.family == "moe":
        m, _ = moe_ffn(cfg, p_l["moe"], h2)
    else:
        m = apply_mlp(cfg, p_l["mlp"], h2)
    if cfg.post_block_norm:
        m = apply_norm(cfg, p_l["post_ln2"], m)
    return x + m, k_l, v_l


def _decode_rwkv_layer(cfg, p_l, s_l, x):
    h, st = R.rwkv_time_mix(
        cfg, p_l["tmix"], apply_norm(cfg, p_l["ln1"], x),
        {"S": s_l["S"], "shift": s_l["shift"]}, use_ref=True,
    )
    x = x + h
    h2, cshift = R.rwkv_channel_mix(
        cfg, p_l["cmix"], apply_norm(cfg, p_l["ln2"], x), s_l["cmix_shift"]
    )
    return x + h2, {"S": st["S"], "shift": st["shift"], "cmix_shift": cshift}


def lm_decode(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,      # (b, 1)
    positions: jnp.ndarray,   # (b,)
    cache: dict,
) -> tuple[jnp.ndarray, dict]:
    x = embed_tokens(cfg, params["embed"], tokens)  # (b, 1, d)

    if cfg.family in ("dense", "vlm", "moe"):
        flags = global_flags(cfg)
        if not cfg.scan_layers:
            ks, vs = [], []
            for i in range(cfg.num_layers):
                x, k_l, v_l = _decode_attn_layer(
                    cfg, _layer_slice(params["layers"], i), x, positions,
                    cache["k"][i], cache["v"][i], bool(flags[i]),
                )
                ks.append(k_l)
                vs.append(v_l)
            new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        else:
            def body(x, layer):
                p_l, is_global, k_l, v_l = layer
                x, k_l, v_l = _decode_attn_layer(
                    cfg, p_l, x, positions, k_l, v_l, is_global
                )
                return x, (k_l, v_l)

            x, (ks, vs) = jax.lax.scan(
                body, x,
                (params["layers"], jnp.asarray(flags), cache["k"], cache["v"]),
            )
            new_cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        if not cfg.scan_layers:
            states = []
            for i in range(cfg.num_layers):
                x, st = _decode_rwkv_layer(
                    cfg, _layer_slice(params["layers"], i),
                    _layer_slice(cache, i), x,
                )
                states.append(st)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        else:
            def body(x, layer):
                p_l, s_l = layer
                return _decode_rwkv_layer(cfg, p_l, s_l, x)

            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    elif cfg.family == "hybrid":
        period = cfg.shared_attn_period
        shared = params["shared"]
        napp = _n_shared_applications(cfg)
        mamba_state = {
            k: cache[k] for k in ("ssm", "conv_x", "conv_B", "conv_C")
        }

        def shared_decode(x, sk, sv, app):
            k_l = jax.lax.dynamic_index_in_dim(sk, app, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(sv, app, 0, keepdims=False)
            h = apply_norm(cfg, shared["ln1"], x)
            h, k_l, v_l = decode_attention_block(
                cfg, shared["attn"], h, positions, k_l, v_l
            )
            x = x + h
            m = apply_mlp(cfg, shared["mlp"], apply_norm(cfg, shared["ln2"], x))
            sk = jax.lax.dynamic_update_index_in_dim(sk, k_l, app, 0)
            sv = jax.lax.dynamic_update_index_in_dim(sv, v_l, app, 0)
            return x + m, sk, sv

        if not cfg.scan_layers:
            sk, sv = cache["shared_k"], cache["shared_v"]
            states = []
            for i in range(cfg.num_layers):
                p_l = _layer_slice(params["layers"], i)
                s_l = _layer_slice(mamba_state, i)
                h, st = M.ssd_block(
                    cfg, p_l["ssd"], apply_norm(cfg, p_l["ln1"], x), s_l,
                    use_ref=True,
                )
                x = x + h
                states.append(st)
                if period and (i + 1) % period == 0:
                    x, sk, sv = shared_decode(x, sk, sv, (i + 1) // period - 1)
            new_mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
            new_cache = {**new_mamba, "shared_k": sk, "shared_v": sv}
        else:
            def body(carry, layer):
                x, idx, sk, sv = carry
                p_l, s_l = layer
                h, st = M.ssd_block(
                    cfg, p_l["ssd"], apply_norm(cfg, p_l["ln1"], x), s_l,
                    use_ref=True,
                )
                x = x + h
                if period:
                    app = ((idx + 1) // period - 1) % max(napp, 1)

                    def do(args):
                        return shared_decode(*args, app)

                    x, sk, sv = jax.lax.cond(
                        (idx + 1) % period == 0,
                        do, lambda a: a, (x, sk, sv),
                    )
                return (x, idx + 1, sk, sv), st

            (x, _, sk, sv), new_mamba = jax.lax.scan(
                body,
                (x, jnp.zeros((), jnp.int32), cache["shared_k"], cache["shared_v"]),
                (params["layers"], mamba_state),
            )
            new_cache = {**new_mamba, "shared_k": sk, "shared_v": sv}
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, new_cache
