"""Golden-trace conformance: numpy vs jax virtual-cluster backends.

The jax kernels (repro.core.vcluster_jax) must be *behaviorally*
interchangeable with the numpy reference: identical completion times,
locality counters, and preemption stats on the golden FB traces, for every
scheduler.  fifo/fair carry no virtual cluster, so their rows pin that the
backend knob is inert where it should be; the hfsp variants exercise the
water-fill, projection, and batched cross-phase warm paths on every
scheduling pass.

Soak seeds: the backend suite runs seeds 0-5 (a superset of the engine
suites' GOLDEN_SEEDS) — the soak requested by the ROADMAP before
defaulting the backend to auto-select jax at scale.

The "auto" rows pin the auto-backend latch (numpy -> jax at the live-job
threshold, repro.core.vcluster.AUTO_JAX_THRESHOLD): with a mid-trace
threshold crossing the run must still be bit-identical to pure numpy —
the latch may change *when* kernels switch, never *what* they compute.
"""

import pytest

from conformance import TRACE_SCHEDULERS, assert_traces_equal, run_trace

pytest.importorskip("jax")

#: Backend-conformance soak seeds (ROADMAP: "soaking the conformance
#: suite on more seeds/workloads" before defaulting to auto-jax).
SOAK_SEEDS = (0, 1, 2, 3, 4, 5)


@pytest.mark.parametrize("seed", SOAK_SEEDS)
@pytest.mark.parametrize("name", TRACE_SCHEDULERS)
def test_backend_conformance(name, seed):
    ref = run_trace(name, seed, vc_backend="numpy")
    jax_run = run_trace(name, seed, vc_backend="jax")
    assert_traces_equal(ref, jax_run)


@pytest.mark.parametrize("name", ("hfsp", "hfsp-kill"))
@pytest.mark.parametrize("seed", (0, 3))
def test_auto_backend_threshold_crossing(name, seed):
    """An "auto" run whose live-job count crosses the latch threshold
    mid-trace (threshold 5 on a 30-job trace) is bit-identical to numpy:
    the backend switch itself is behavior-neutral."""
    ref = run_trace(name, seed, vc_backend="numpy")
    auto = run_trace(name, seed, vc_backend="auto", vc_auto_threshold=5)
    assert_traces_equal(ref, auto)


def test_auto_backend_actually_latches():
    """The threshold-crossing test above is only meaningful if the latch
    really fires on this trace — pin it (guards against a silently
    ineffective auto mode)."""
    from repro.core import HFSPConfig, HFSPScheduler, Simulator
    from repro.core.types import Phase
    from repro.workload import fb_cluster, fb_dataset

    cluster = fb_cluster(num_machines=20)
    jobs, _ = fb_dataset(seed=0, num_jobs=30)
    sch = HFSPScheduler(
        cluster, HFSPConfig(vc_backend="auto", vc_auto_threshold=5)
    )
    assert sch.vc[Phase.MAP].backend == "numpy"
    Simulator(cluster, sch, jobs).run()
    assert sch.vc[Phase.MAP].backend == "jax"
