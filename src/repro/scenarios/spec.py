"""Declarative scenario specifications (the experiment matrix, Sect. 4).

A :class:`ScenarioSpec` names one *cell* of the paper's evaluation matrix
by composing orthogonal axes:

* **workload** — what jobs arrive (fb / fb_scaled / ml / a recorded JSONL
  trace), at what scale and seed;
* **cluster**  — machines and per-machine slot shape (Sect. 4.1's Amazon
  cluster by default);
* **scheduler** — policy (fifo / fair / hfsp), preemption primitive,
  size-estimation error model (Fig. 6), virtual-cluster numeric backend;
* **sim**      — executor knobs (heartbeat).

Specs are frozen, hashable, and round-trip losslessly through plain JSON
dicts (`to_dict` / `from_dict`) — the sweep engine's on-disk result store
keys cells by `cell_id()` + `spec_hash()` so an interrupted sweep can
resume without recomputing finished cells.

Everything downstream (the runner, the sweep engine, the benchmarks, the
CLI) consumes only this vocabulary; adding an axis here makes it available
to every preset and sweep at once.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, fields, is_dataclass, replace

#: Schema version of the dict/JSON form of a ScenarioSpec (bumped on any
#: field addition/rename so stored sweep results can detect staleness).
SPEC_VERSION = 1

WORKLOAD_KINDS = ("fb", "fb_scaled", "ml", "trace")
#: The built-in scheduling disciplines (informational; the authoritative
#: set is the discipline registry, ``repro.core.disciplines.names()``,
#: which third-party code extends at runtime).  Policy names are NOT
#: validated at spec construction — a spec is plain data and must be
#: able to name a discipline that is registered later; resolution (and
#: the unknown-name error listing what IS registered) happens in
#: :func:`repro.scenarios.runner.build_scheduler`.
POLICIES = ("fifo", "fair", "hfsp", "srpt", "las", "psbs")
PREEMPTIONS = ("eager", "wait", "kill")


@dataclass(frozen=True)
class WorkloadAxis:
    """What arrives: generator kind + its knobs.

    ``kind="trace"`` replays a recorded JSONL trace (see
    :mod:`repro.scenarios.trace`) through the same simulator — golden
    traces are just another scenario.
    """

    kind: str = "fb"
    seed: int = 0
    num_jobs: int = 100
    #: Strip REDUCE tasks (the paper's MAP-only FB variant, Sect. 4.3).
    map_only: bool = False
    #: Intra-job task-time skew (lognormal sigma; 0 = none, the paper).
    task_jitter: float = 0.0
    #: Machines holding HDFS input replicas.  None = the cluster's machine
    #: count.  Pin it explicitly when sweeping cluster.num_machines so the
    #: workload (placement AND the shared RNG stream behind arrivals/
    #: durations) stays identical across the size axis — hosts beyond the
    #: cluster are simply permanent locality misses (paper-cluster-size
    #: pins 100, the Fig. 5 convention).
    num_hosts: int | None = None
    #: kind="trace": path to the JSONL trace file.
    trace_path: str | None = None

    def __post_init__(self) -> None:
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(
                f"unknown workload kind {self.kind!r}; expected {WORKLOAD_KINDS}"
            )
        if self.kind == "trace" and not self.trace_path:
            raise ValueError("workload kind 'trace' requires trace_path")


@dataclass(frozen=True)
class ClusterAxis:
    """Cluster shape (defaults = the paper's Amazon cluster, Sect. 4.1)."""

    num_machines: int = 100
    map_slots: int = 4
    reduce_slots: int = 2
    #: TPU adaptation: EAGER suspend/resume DMA bandwidth (0 = free).
    dma_bandwidth: float = 0.0


@dataclass(frozen=True)
class SchedulerAxis:
    """Policy + preemption + estimation-error model + vcluster backend.

    ``policy`` names a discipline in the registry
    (:mod:`repro.core.disciplines`).  It is validated lazily, at
    scheduler-build time — not here — so specs and sweeps can be
    constructed over disciplines registered from user code (or not yet
    imported); an unknown name fails at resolve time with the list of
    registered disciplines.
    """

    policy: str = "hfsp"
    #: Preemption primitive for the engine-family disciplines (hfsp,
    #: srpt, las, psbs, and custom engine assemblies); fifo/fair never
    #: preempt and ignore it.
    preemption: str = "eager"
    #: Fig. 6 error model: finalized estimates perturbed uniformly in
    #: [s*(1-alpha), s*(1+alpha)].
    error_alpha: float = 0.0
    error_seed: int = 0
    sample_set_size: int = 5
    delta: float = 60.0
    #: Virtual-cluster numeric backend (None = auto-select, see
    #: repro.core.vcluster.resolve_backend).
    vc_backend: str | None = None
    #: PSBS calibration knobs (repro.core.disciplines._build_psbs; the
    #: ``paper-psbs-calibration`` preset sweeps them): late-job
    #: re-injection aggressiveness and the rank-stability spread the
    #: preemption hysteresis tolerates.  Ignored by every other policy.
    #: At their defaults these fields are *omitted* from ``to_dict`` —
    #: like a disabled FaultAxis — so every pre-existing spec hash (and
    #: therefore every stored sweep result) stays valid.
    psbs_late_factor: float = 1.0
    psbs_max_spread: int = 0

    def __post_init__(self) -> None:
        if self.preemption not in PREEMPTIONS:
            raise ValueError(
                f"unknown preemption {self.preemption!r}; expected {PREEMPTIONS}"
            )


@dataclass(frozen=True)
class FaultAxis:
    """Fault-injection axis — field-for-field mirror of
    :class:`repro.core.faults.FaultModel` (the runner converts with
    ``FaultModel(**asdict(axis))``), kept separate so the declarative
    layer stays import-light and plain-JSON.

    All rates default to 0: a default axis is disabled, and a disabled
    axis is *omitted* from ``to_dict`` so every pre-fault spec hash (and
    therefore every stored sweep result) stays valid.
    """

    seed: int = 0
    machine_mtbf: float = 0.0
    machine_mttr: float = 60.0
    task_fail_rate: float = 0.0
    max_task_retries: int = 5
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    straggler_prob: float = 0.0
    straggler_factor: float = 3.0
    sample_loss_rate: float = 0.0
    blacklist_threshold: int = 3
    probation_s: float = 120.0
    speculation: bool = True
    speculation_min_remaining: float = 1.0

    @property
    def enabled(self) -> bool:
        return (
            self.machine_mtbf > 0.0
            or self.task_fail_rate > 0.0
            or self.straggler_prob > 0.0
            or self.sample_loss_rate > 0.0
        )


@dataclass(frozen=True)
class ScenarioSpec:
    """One fully-specified experiment cell."""

    name: str = "scenario"
    workload: WorkloadAxis = field(default_factory=WorkloadAxis)
    cluster: ClusterAxis = field(default_factory=ClusterAxis)
    scheduler: SchedulerAxis = field(default_factory=SchedulerAxis)
    heartbeat: float = 3.0
    #: Simulator epsilon-window event coalescing (seconds; 0 = a pass per
    #: event, bit-identical to the legacy loop — see
    #: repro.core.simulator.SimConfig.event_epsilon).  A spec axis so
    #: sweeps can report the sojourn-vs-scheduler-overhead tradeoff per
    #: cell (the ``paper-fb-eps`` preset).  The string ``"auto"`` derives
    #: the width from the materialized workload's arrival burstiness
    #: (repro.core.simulator.auto_event_epsilon) — still deterministic
    #: per cell, since the workload is a pure function of the spec.
    event_epsilon: float | str = 0.0
    #: Fault injection (machine churn, task failures, stragglers, sample
    #: loss — see repro.core.faults and the ``paper-faults`` preset).
    faults: FaultAxis = field(default_factory=FaultAxis)

    # -- JSON round-trip -----------------------------------------------------
    def to_dict(self) -> dict:
        sched = _axis_dict(self.scheduler)
        # Default-valued psbs knobs are omitted (the FaultAxis rule: a
        # knob at its default must not perturb the hash, so spec hashes
        # minted before the knob existed — and every stored sweep
        # result keyed by them — stay valid).
        for knob in ("psbs_late_factor", "psbs_max_spread"):
            if sched[knob] == _SCHEDULER_DEFAULTS[knob]:
                del sched[knob]
        d = {
            "version": SPEC_VERSION,
            "name": self.name,
            "workload": _axis_dict(self.workload),
            "cluster": _axis_dict(self.cluster),
            "scheduler": sched,
            "heartbeat": self.heartbeat,
            "event_epsilon": self.event_epsilon,
        }
        # Only when enabled: a disabled axis must not perturb the hash
        # of pre-fault specs (stored sweep results stay resumable).
        if self.faults.enabled:
            d["faults"] = _axis_dict(self.faults)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        v = d.get("version", SPEC_VERSION)
        if v != SPEC_VERSION:
            raise ValueError(
                f"scenario spec version {v} != supported {SPEC_VERSION}"
            )
        return cls(
            name=d.get("name", "scenario"),
            workload=WorkloadAxis(**d.get("workload", {})),
            cluster=ClusterAxis(**d.get("cluster", {})),
            scheduler=SchedulerAxis(**d.get("scheduler", {})),
            heartbeat=d.get("heartbeat", 3.0),
            event_epsilon=d.get("event_epsilon", 0.0),
            faults=FaultAxis(**d.get("faults", {})),
        )

    # -- identity ------------------------------------------------------------
    def spec_hash(self) -> str:
        """Stable content hash (sweep stores key results by it so a spec
        edit invalidates stale cells instead of silently reusing them)."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- axis overrides ------------------------------------------------------
    def override(self, **dotted) -> "ScenarioSpec":
        """Return a copy with dotted-path overrides applied, e.g.
        ``spec.override(**{"scheduler.policy": "fair", "workload.seed": 3})``.
        Top-level fields use their plain name (``heartbeat=...``).
        Overrides touching one axis are applied together, so co-dependent
        fields (e.g. ``workload.kind="trace"`` + ``workload.trace_path``)
        validate against the combined state."""
        by_axis: dict[str, dict[str, object]] = {}
        top: dict[str, object] = {}
        for path, value in dotted.items():
            if "." in path:
                axis_name, leaf = path.split(".", 1)
                axis = getattr(self, axis_name, None)
                if (
                    not is_dataclass(axis)
                    or not any(f.name == leaf for f in fields(axis))
                ):
                    raise KeyError(f"unknown scenario field {path!r}")
                by_axis.setdefault(axis_name, {})[leaf] = value
            else:
                if not any(f.name == path for f in fields(self)):
                    raise KeyError(f"unknown scenario field {path!r}")
                top[path] = value
        changes: dict[str, object] = dict(top)
        for axis_name, leaves in by_axis.items():
            changes[axis_name] = replace(getattr(self, axis_name), **leaves)
        return replace(self, **changes)

    def quick(self) -> "ScenarioSpec":
        """Reduced-scale variant for smoke sweeps: same matrix axes, small
        trace (30 jobs, 20 machines for the fb kinds).  Deterministic —
        the quick cell is itself a well-defined scenario."""
        if self.workload.kind in ("fb", "fb_scaled"):
            out = self.override(**{
                "workload.num_jobs": min(self.workload.num_jobs, 30),
                "cluster.num_machines": min(self.cluster.num_machines, 20),
            })
        elif self.workload.kind == "ml":
            out = self.override(**{
                "workload.num_jobs": min(self.workload.num_jobs, 12),
            })
        else:
            out = self
        return replace(out, name=out.name + "@quick")


def _axis_dict(axis) -> dict:
    return {f.name: getattr(axis, f.name) for f in fields(axis)}


#: SchedulerAxis field defaults (for the to_dict omit-at-default rule).
_SCHEDULER_DEFAULTS = {f.name: f.default for f in fields(SchedulerAxis)}


# ---------------------------------------------------------------------------
# Sweeps: a parameter grid over a base scenario
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SweepSpec:
    """A named experiment = union of parameter grids over a base scenario.

    Each grid maps a dotted axis path (see :meth:`ScenarioSpec.override`)
    to the values it takes; a grid expands to the cartesian product of its
    axes and the sweep to the (de-duplicated) union of its grids.  Multiple
    grids express non-rectangular matrices — e.g. Fig. 6's HFSP
    error-alpha x error-seed grid plus a single error-independent FAIR
    reference cell.

    Per-cell seeding is deterministic by construction: any RNG seed
    (workload seed, estimator error seed) is an explicit axis value baked
    into the cell's spec, so a cell's result is a pure function of the
    cell — the contract the resumable result store relies on.
    """

    name: str
    base: ScenarioSpec
    grids: tuple[tuple[tuple[str, tuple], ...], ...] = ((),)

    @staticmethod
    def grid(**axes) -> tuple[tuple[str, tuple], ...]:
        """One grid: ``SweepSpec.grid(**{"scheduler.policy": ["fifo"]})``."""
        return tuple((k, tuple(v)) for k, v in axes.items())

    def expand(self) -> list[tuple[str, ScenarioSpec]]:
        """[(cell_id, spec)] — deterministic order, duplicates dropped."""
        cells: list[tuple[str, ScenarioSpec]] = []
        seen: set[str] = set()
        for grid in self.grids:
            for combo in _product(grid):
                spec = self.base.override(**dict(combo))
                cid = cell_id(combo)
                if cid not in seen:
                    seen.add(cid)
                    cells.append((cid, spec))
        return cells


def cell_id(combo: tuple[tuple[str, object], ...]) -> str:
    """Human-readable deterministic cell key, e.g.
    ``scheduler.policy=hfsp,workload.seed=2`` (empty combo -> ``base``)."""
    if not combo:
        return "base"
    return ",".join(f"{k}={v}" for k, v in sorted(combo))


def parse_cell_id(cid: str) -> dict[str, str]:
    """Inverse of :func:`cell_id`: {dotted-path: value-as-string}.

    The single decoder for every cell-id consumer (benchmarks, examples)
    — values are returned as strings, the caller casts.  Note the format
    does not escape ``,``/``=``; axes whose *values* contain them (e.g. a
    swept trace_path) are not representable and a sweep over them should
    key cells differently.
    """
    if cid == "base":
        return {}
    return dict(part.split("=", 1) for part in cid.split(","))


def _product(grid: tuple[tuple[str, tuple], ...]):
    """Cartesian product of one grid's axes as override tuples."""
    if not grid:
        yield ()
        return
    (key, values), rest = grid[0], grid[1:]
    for v in values:
        for tail in _product(rest):
            yield ((key, v),) + tail
