"""Pure-jnp oracle for the rwkv6 kernel: the sequential scan from
repro.models.rwkv in the kernel's (b, h, t, d) layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.rwkv import rwkv_scan_ref


def rwkv6_ref(r, k, v, w, u, s0):
    """(b,h,t,d) layout -> (out, final_state), fp32."""
    to_bt = lambda x: jnp.moveaxis(x, 1, 2)   # (b,h,t,d) -> (b,t,h,d)
    out, s = rwkv_scan_ref(
        to_bt(r).astype(jnp.float32),
        to_bt(k).astype(jnp.float32),
        to_bt(v).astype(jnp.float32),
        to_bt(w).astype(jnp.float32),
        u.astype(jnp.float32),
        s0.astype(jnp.float32),
    )
    return jnp.moveaxis(out, 2, 1), s
