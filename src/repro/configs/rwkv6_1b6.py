"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; unverified]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                # d_model / rwkv_head_dim
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    norm="layernorm",
    tie_embeddings=False,        # rwkv uses separate head
)

SMOKE = reduced(CONFIG, num_heads=4)
