"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; vision frontend is a STUB (input_specs
provides precomputed patch embeddings) [hf:llava-hf; unverified]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    act="silu_glu",
    norm="rmsnorm",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=5_000_000.0,
    num_patches=2880,            # anyres: base 576 + 4 tiles x 576
)

SMOKE = reduced(CONFIG)
