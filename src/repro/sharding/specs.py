"""PartitionSpec rules: DP / TP / EP / SP sharding for every arch family.

The mesh axes are ("data", "model") single-pod and ("pod", "data", "model")
multi-pod (see launch/mesh.py).  Parameters are tensor-parallel over
"model" and replicated over "data"/"pod"; batches are data-parallel over
("pod", "data"); KV caches shard heads over "model" when divisible, and
the *sequence* axis over "data" when the batch is too small to split
(long_500k, batch=1 — the flash-decode layout).

Every rule degrades gracefully: an axis is applied only when the dimension
is divisible by the mesh axis size, otherwise that dim is replicated (e.g.
command-r's 8 kv heads on a 16-way model axis).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _shard_if(mesh: Mesh, dim: int, axis) -> Any:
    """Return the axis name if ``dim`` divides evenly, else None."""
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# ---------------------------------------------------------------------------
# Parameter specs (path-rule based)
# ---------------------------------------------------------------------------
def _leaf_spec(mesh: Mesh, path: tuple[str, ...], shape: tuple[int, ...],
               cfg: ModelConfig | None = None) -> P:
    name = path[-1]
    ctx = set(path)
    # leading layer axis from scan stacking (decoder-only stacks use
    # "layers"; whisper uses "encoder"/"decoder")
    stacked = bool(ctx & {"layers", "encoder", "decoder"})
    lead = (None,) if stacked else ()

    def pspec(*dims):
        return P(*lead, *dims)

    # -- embeddings -------------------------------------------------------
    if name == "embedding":
        return P(_shard_if(mesh, shape[0], "model"), None)
    if name == "unembed":
        return P(None, _shard_if(mesh, shape[-1], "model"))
    if name in ("enc_pos", "dec_pos"):
        return P(None, None)

    # -- attention ----------------------------------------------------------
    if "attn" in ctx or "self_attn" in ctx or "cross_attn" in ctx:
        heads = shape[-2] if name in ("wq", "wk", "wv") else None
        if name in ("wq", "wk", "wv"):
            return pspec(None, _shard_if(mesh, shape[-2], "model"), None)
        if name == "wo":
            return pspec(_shard_if(mesh, shape[-3], "model"), None, None)
        if name in ("bq", "bk", "bv"):
            return pspec(_shard_if(mesh, shape[-2], "model"), None)
        if name == "bo":
            return pspec(None)

    # -- MoE (expert weights; the shared expert is a plain MLP) -----------------
    if "moe" in ctx and "shared" not in ctx:
        if name == "router":
            return pspec(None, None)
        if name in ("wi", "wg"):   # (E, d, f): expert-local TP on f
            return pspec(None, None, _shard_if(mesh, shape[-1], "model"))
        if name == "wo":           # (E, f, d)
            return pspec(None, _shard_if(mesh, shape[-2], "model"), None)
        if name in ("bi",):
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name in ("bo",):
            return pspec(None, None)

    # -- MLP (incl. moe shared expert / zamba2 shared block) --------------------
    if "mlp" in ctx or "cmix" in ctx or "shared" in ctx:
        if name in ("wi", "wg", "wk"):   # (d, f)
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name in ("wo", "wv"):         # (f, d)
            return pspec(_shard_if(mesh, shape[-2], "model"), None)
        if name == "wr":                 # rwkv cmix receptance (d, d)
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name == "bi":
            return pspec(_shard_if(mesh, shape[-1], "model"))
        if name == "bo":
            return pspec(None)
        if name == "mu":
            return pspec(None, None)

    # -- RWKV time mix -----------------------------------------------------------
    if "tmix" in ctx:
        if name in ("wr", "wk", "wv", "wg"):   # (d, d): head-major out dim
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name == "wo":
            return pspec(_shard_if(mesh, shape[-2], "model"), None)
        if name == "bonus_u":                  # (h, dk)
            return pspec(_shard_if(mesh, shape[-2], "model"), None)
        if name in ("wd_a", "wd_b"):
            return pspec(None, None)
        if name in ("wd_bias", "ln_x_scale"):
            return pspec(_shard_if(mesh, shape[-1], "model"))
        if name == "mu":
            return pspec(None, None)

    # -- SSD (mamba2) ---------------------------------------------------------------
    if "ssd" in ctx:
        if name in ("wz", "wx"):               # (d, d_inner)
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name == "w_out":                    # (d_inner, d)
            return pspec(_shard_if(mesh, shape[-2], "model"), None)
        if name in ("conv_x_w",):              # (K, d_inner)
            return pspec(None, _shard_if(mesh, shape[-1], "model"))
        if name in ("conv_x_b", "norm_scale"):
            return pspec(_shard_if(mesh, shape[-1], "model"))
        if name in ("wB", "wC", "wdt", "conv_B_w", "conv_C_w",
                    "conv_B_b", "conv_C_b", "A_log", "D", "dt_bias"):
            return pspec(*(None,) * (len(shape) - (1 if stacked else 0)))

    # -- norms / everything small: replicate ------------------------------------------
    return P(*(None,) * len(shape))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """Build a PartitionSpec pytree matching ``params_shape`` (a pytree of
    ShapeDtypeStruct, e.g. from jax.eval_shape(init_model...))."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return _leaf_spec(mesh, path, tuple(node.shape), cfg)

    return walk((), params_shape)


def state_specs(cfg: ModelConfig, mesh: Mesh, state_shape, *,
                zero_opt: bool = False) -> Any:
    """{"params": ..., "opt": {"m","v","step"}} spec tree.

    ``zero_opt`` (§Perf, ZeRO-2-style): Adam moments additionally shard
    their leading (layer-stack) dim over 'data' when divisible — gradients
    then reduce-scatter into the moment shards and the Adam update is
    1/16th the work and memory per device; parameters stay replicated over
    data for a cheap forward."""
    pspecs = param_specs(cfg, mesh, state_shape["params"])

    def zero(spec_node, shape_node):
        if isinstance(spec_node, dict):
            return {
                k: zero(spec_node[k], shape_node[k]) for k in spec_node
            }
        dims = list(spec_node)
        shp = tuple(shape_node.shape)
        if (
            dims
            and dims[0] is None
            and len(shp) >= 2
            and shp[0] % _axis_size(mesh, "data") == 0
        ):
            dims[0] = "data"
            return P(*dims)
        return spec_node

    mspecs = (
        zero(pspecs, state_shape["params"]) if zero_opt else pspecs
    )
    return {
        "params": pspecs,
        "opt": {"m": mspecs, "v": mspecs, "step": P()},
    }


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> dict:
    dp = dp_axes(mesh)
    bdim = _shard_if(mesh, shape.global_batch, dp)
    out = {"tokens": P(bdim, None)}
    if shape.kind == "train":
        out["labels"] = P(bdim, None)
    if shape.kind == "decode":
        out["positions"] = P(bdim)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = P(bdim, None, None)
    if cfg.family == "encdec" and shape.kind != "decode":
        out["frame_embeds"] = P(bdim, None, None)
    return out


def cache_specs_sharding(
    cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, cache_shape
) -> Any:
    """Sharding for the decode cache.  batch >= data => batch-shard;
    batch == 1 (long_500k) => shard the cache *sequence* over data
    (flash-decode: partial attention per shard, combined by GSPMD)."""
    dp = dp_axes(mesh)
    b = shape.global_batch
    batch_ok = b % _axis_size(mesh, dp) == 0

    def leaf(path, node):
        name = path[-1]
        shp = tuple(node.shape)
        if name in ("k", "v", "shared_k", "shared_v", "xk", "xv"):
            # (L, b, S, kvh, hs)
            kvh_axis = _shard_if(mesh, shp[3], "model")
            if batch_ok:
                return P(None, dp, None, kvh_axis, None)
            return P(None, None, _shard_if(mesh, shp[2], "data"), kvh_axis, None)
        if name == "ssm":       # (L, b, h, p, n)
            return P(
                None, dp if batch_ok else None,
                _shard_if(mesh, shp[2], "model"), None, None,
            )
        if name == "S":         # rwkv (L, b, h, dk, dv)
            return P(
                None, dp if batch_ok else None,
                _shard_if(mesh, shp[2], "model"), None, None,
            )
        if name in ("shift", "cmix_shift"):   # (L, b, d)
            return P(None, dp if batch_ok else None, None)
        if name.startswith("conv_"):          # (L, b, K-1, c)
            return P(
                None, dp if batch_ok else None, None,
                _shard_if(mesh, shp[3], "model"),
            )
        return P(*(None,) * len(shp))

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return leaf(path, node)

    return walk((), cache_shape)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
