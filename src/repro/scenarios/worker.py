"""Distributed sweep worker: lease-claiming cell executor.

``run_worker`` is the standalone counterpart of the local self-healing
supervisor in :mod:`repro.scenarios.sweep` — any number of workers on
any number of machines point at one shared store
(``python -m repro.scenarios worker <preset> --store ...``) and the
sweep converges exactly-once:

1. **claim** — pick the first pending cell (deterministic spec order)
   not covered by a live foreign lease and claim it with a TTL'd lease
   row; losing a claim race just moves on to the next cell.
2. **compute** — run the cell in a spawned per-attempt process with PR
   6's self-healing semantics unchanged: per-attempt wall-clock
   ``timeout`` kill, bounded retry with capped exponential backoff,
   quarantine record past the retry budget.  The lease is renewed every
   ``renew_every`` seconds while the attempt runs (and across retry
   backoffs), so only a *dead* worker's lease expires.
3. **store** — append the result; a duplicate (some other worker won a
   race on this cell) is detected by the store, dropped, and counted.
   Then release the lease.
4. **converge** — loop until every cell of the sweep is stored.  With
   cells left but nothing claimable (live foreign leases), idle-poll;
   workers heartbeat each loop so ``sweep-status`` can report liveness.

A SIGKILLed worker stops renewing; once its lease TTL passes, any other
worker's claim takes the cell over (a counted *reissue*).  The attempt
child it may have left behind is harmless: results are only appended by
worker loops, and an orphaned child dies on its broken result pipe.

This module also owns the per-attempt primitives (spawned process entry
point, test-fault hooks, quarantine record) shared with the local
supervisor — workers use the ``spawn`` start method because the parent
may hold jax state (the vcluster jax backend), which does not survive
``fork``.
"""

from __future__ import annotations

import json
import os
import socket
import time
from pathlib import Path

from repro.scenarios.lease import DEFAULT_TTL, LeaseKeeper
from repro.scenarios.runner import run_scenario
from repro.scenarios.spec import ScenarioSpec, SweepSpec
from repro.scenarios.store import SweepStore, open_store

#: Env var naming a JSON file of test-only worker fault hooks —
#: ``{"hang_once": [cell_ids], "fail_always": [cell_ids], "slow_once":
#: {"cells": [...] | "*", "seconds": s}, "state_dir": path}`` — read
#: inside the *spawned* attempt process (a spawn child cannot see parent
#: monkeypatches, so the self-healing and chaos tests inject
#: hangs/failures/delays through the environment instead).
_TEST_HOOK_ENV = "_REPRO_SWEEP_TEST_HOOK"


def _quarantine_record(cid: str, error: str, attempts: int) -> dict:
    """The poison-cell record stored in place of a scenario report."""
    return {
        "quarantined": True,
        "cell_id": cid,
        "error": error,
        "attempts": attempts,
    }


def _run_cell(payload: tuple[str, dict]) -> tuple[str, dict]:
    """Compute one cell from its serialized spec."""
    cid, spec_dict = payload
    return cid, run_scenario(ScenarioSpec.from_dict(spec_dict))


def _apply_test_hook(cid: str) -> None:
    path = os.environ.get(_TEST_HOOK_ENV)
    if not path:
        return
    with open(path) as f:
        hook = json.load(f)
    if cid in hook.get("fail_always", ()):
        raise RuntimeError(f"sweep test hook: cell {cid!r} fails")
    if cid in hook.get("hang_once", ()):
        marker = Path(hook["state_dir"]) / f"hung-{cid}"
        if not marker.exists():
            marker.write_text("hung once\n")
            time.sleep(3600.0)  # until the supervisor's timeout kills us
    slow = hook.get("slow_once") or {}
    cells = slow.get("cells", ())
    if cells == "*" or cid in cells:
        # First attempt of the cell sleeps (stretching the SIGKILL
        # window for chaos tests); reclaimed attempts run at full speed.
        marker = Path(hook["state_dir"]) / f"slow-{cid}"
        if not marker.exists():
            marker.write_text("slowed once\n")
            time.sleep(float(slow.get("seconds", 1.0)))


def _cell_worker(conn, cid: str, spec_dict: dict) -> None:
    """Spawned per-attempt process entry point: compute the cell, send
    ("ok", report) or ("err", repr) back over the pipe."""
    try:
        _apply_test_hook(cid)
        _, result = _run_cell((cid, spec_dict))
        conn.send(("ok", result))
    except BaseException as e:  # noqa: BLE001 - reported to the supervisor
        try:
            conn.send(("err", repr(e)))
        except Exception:
            pass
    finally:
        conn.close()


def default_worker_id() -> str:
    """hostname-pid: unique per worker loop across a shared filesystem."""
    return f"{socket.gethostname()}-{os.getpid()}"


def _attempt_once(
    cid: str, spec_dict: dict, timeout: float | None, on_tick=None
) -> tuple[str, object]:
    """One supervised spawned attempt; returns ("ok", report) or
    ("err", reason).  ``on_tick`` runs every poll interval (the worker
    renews its lease there)."""
    import multiprocessing

    ctx = multiprocessing.get_context("spawn")
    parent_conn, child_conn = ctx.Pipe(duplex=False)
    proc = ctx.Process(
        target=_cell_worker, args=(child_conn, cid, spec_dict), daemon=True
    )
    proc.start()
    child_conn.close()
    started = time.monotonic()
    try:
        while True:
            if parent_conn.poll(0.1):
                try:
                    msg = parent_conn.recv()
                except (EOFError, OSError):
                    msg = ("err", "worker process died without sending a result")
                break
            if (
                timeout is not None
                and time.monotonic() - started > timeout
            ):
                msg = ("err", f"timeout: exceeded {timeout}s wall clock")
                break
            if on_tick is not None:
                on_tick()
    finally:
        parent_conn.close()
        if proc.is_alive():
            proc.terminate()
            proc.join(5.0)
            if proc.is_alive():  # pragma: no cover - hard hang
                proc.kill()
        proc.join(5.0)
    return msg


def _compute_with_retries(
    cid: str,
    spec: ScenarioSpec,
    keeper: LeaseKeeper,
    *,
    timeout: float | None,
    max_retries: int,
    retry_backoff: float,
) -> dict:
    """PR 6 self-healing semantics around ``_attempt_once``: bounded
    retry with capped exponential backoff, quarantine past the budget.
    The lease keeper ticks through attempts *and* backoff sleeps."""
    spec_dict = spec.to_dict()
    n_fails = 0
    while True:
        kind, payload = _attempt_once(cid, spec_dict, timeout, keeper.tick)
        if kind == "ok":
            return payload
        n_fails += 1
        if n_fails > max_retries:
            return _quarantine_record(cid, str(payload), n_fails)
        deadline = time.monotonic() + retry_backoff * (2.0 ** (n_fails - 1))
        while time.monotonic() < deadline:
            keeper.tick()
            time.sleep(min(0.05, max(0.0, deadline - time.monotonic())))


def run_worker(
    sweep: SweepSpec,
    store: SweepStore | str | Path,
    *,
    worker_id: str | None = None,
    ttl: float = DEFAULT_TTL,
    renew_every: float | None = None,
    timeout: float | None = 600.0,
    max_retries: int = 2,
    retry_backoff: float = 0.5,
    poll: float = 0.5,
    max_cells: int | None = None,
    deadline: float | None = None,
    progress=None,
) -> dict:
    """Run one worker loop against a shared store until the sweep
    converges (every cell stored) or ``max_cells``/``deadline`` stops it.

    ``ttl``/``renew_every`` shape the lease protocol (renew defaults to
    ttl/3); ``timeout``/``max_retries``/``retry_backoff`` are PR 6's
    self-healing knobs, unchanged; ``poll`` is the idle wait when every
    pending cell is covered by a live foreign lease; ``deadline`` bounds
    the loop's total wall clock (seconds) — on expiry the worker exits
    with ``"stalled": True`` instead of waiting forever on leases that
    other (possibly wedged) workers hold.  Returns a summary dict with
    the cells this worker computed and the store's coordination stats.
    """
    store = open_store(store)
    wid = worker_id or default_worker_id()
    cells = sweep.expand()
    spec_of = dict(cells)
    hashes = {cid: spec.spec_hash() for cid, spec in cells}
    t_end = None if deadline is None else time.monotonic() + deadline
    summary = {
        "worker": wid,
        "computed": [],
        "duplicates_dropped": 0,
        "claims_lost": 0,
        "leases_lost": 0,
        "stalled": False,
    }

    while True:
        store.heartbeat(
            wid,
            info={
                "host": socket.gethostname(),
                "pid": os.getpid(),
                "done": len(summary["computed"]),
            },
        )
        done = store.load()
        todo = [cid for cid, _ in cells if (cid, hashes[cid]) not in done]
        if not todo:
            break
        if t_end is not None and time.monotonic() > t_end:
            summary["stalled"] = True
            break
        now = time.time()
        held = store.leases()
        got = None
        for cid in todo:
            lease = held.get((cid, hashes[cid]))
            if lease is not None and lease.worker != wid and not lease.expired(now):
                continue  # live foreign lease — someone is on it
            if store.claim(cid, hashes[cid], wid, ttl):
                got = cid
                break
            summary["claims_lost"] += 1
        if got is None:
            time.sleep(poll)
            continue
        keeper = LeaseKeeper(
            store, got, hashes[got], wid, ttl, renew_every=renew_every
        )
        result = _compute_with_retries(
            got,
            spec_of[got],
            keeper,
            timeout=timeout,
            max_retries=max_retries,
            retry_backoff=retry_backoff,
        )
        if not store.append(got, hashes[got], result):
            summary["duplicates_dropped"] += 1
        store.release(got, hashes[got], wid)
        if keeper.lost:
            summary["leases_lost"] += 1
        summary["computed"].append(got)
        if progress is not None:
            progress(got, result)
        if max_cells is not None and len(summary["computed"]) >= max_cells:
            break

    summary["stats"] = store.stats()
    return summary
