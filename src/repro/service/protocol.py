"""Line-JSON wire protocol: one JSON object per ``\\n``-terminated line.

Both clients and workers speak it over a plain TCP stream — asyncio on
the master side, and a small blocking client here for tests, scripts
and the CLI (no extra dependency either way).

Client ops (request -> one reply):

* ``{"op": "submit", "user": U, "tag": T, "job": {...}}`` ->
  ``{"ok": true, "job_id": N, "decision": "admit"|"queued"}`` or
  ``{"ok": false, "error": "reject-rate"|"reject-queue"}``.  ``job``
  uses the repro-trace task schema (``map``/``reduce`` lists of
  ``[duration, input_hosts, state_bytes]`` plus ``name``/``weight``/
  ``reduce_slowstart``); ``tag`` is an idempotency token — resubmitting
  the same tag returns the original job id instead of a duplicate.
* ``{"op": "job", "job_id": N}`` -> ``{"ok": true, "state":
  "queued"|"live"|"done", "completion_t": ...}``
* ``{"op": "status"}`` -> one telemetry snapshot
  (:meth:`repro.service.telemetry.Telemetry.snapshot`).
* ``{"op": "telemetry", "ticks": K, "interval": s}`` -> streams K
  snapshot lines, ``interval`` wall-seconds apart (the live metrics
  feed).
* ``{"op": "checkpoint"}`` -> forces a checkpoint write.
* ``{"op": "shutdown"}`` -> graceful stop.

Worker ops (persistent duplex connection, no request pairing):

* worker -> master: ``{"op": "register", "machine": M}``,
  ``{"op": "heartbeat", "machine": M}``, ``{"op": "task_done", ...}``
  (advisory — the engine's completions are authoritative);
* master -> worker: ``{"op": "launch", "key": [...], "machine": M,
  "wall_s": s}``, ``{"op": "suspend"|"resume"|"kill", "key": [...]}``.
"""

from __future__ import annotations

import json
import socket

MAX_LINE = 1 << 20


def encode(obj: dict) -> bytes:
    return (json.dumps(obj, sort_keys=True) + "\n").encode()


def decode(line: bytes) -> dict:
    return json.loads(line.decode())


async def send(writer, obj: dict) -> None:
    writer.write(encode(obj))
    await writer.drain()


async def recv(reader) -> dict | None:
    """One message, or None on EOF/oversize (treat both as disconnect)."""
    try:
        line = await reader.readline()
    except (ConnectionError, ValueError):
        return None
    if not line or len(line) > MAX_LINE:
        return None
    try:
        return decode(line)
    except json.JSONDecodeError:
        return None


class ServiceClient:
    """Blocking request/reply client (tests, scripts, CLI)."""

    def __init__(self, host: str, port: int, timeout: float = 10.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._f = self._sock.makefile("rwb")

    def call(self, msg: dict) -> dict:
        self._f.write(encode(msg))
        self._f.flush()
        line = self._f.readline()
        if not line:
            raise ConnectionError("master closed the connection")
        return decode(line)

    def read_line(self) -> dict | None:
        """Next pushed line (telemetry streaming)."""
        line = self._f.readline()
        return decode(line) if line else None

    def close(self) -> None:
        try:
            self._f.close()
        finally:
            self._sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
