"""Fault-injection subsystem: determinism goldens, inertness, robustness.

The contracts pinned here (docs/faults.md):

* **inertness** — with every fault rate zero the fault machinery is
  provably absent: summaries (completions, stats, pass counts) are
  bit-identical to a run with no FaultModel at all, and the result
  carries no fault block;
* **determinism goldens** — the same ``FaultModel`` seed produces an
  identical ordered failure trace AND identical completions on rerun,
  at each ``event_epsilon`` in {0, 0.5}, and across the numpy / jax /
  auto virtual-cluster backends.  (eps=0 vs eps=0.5 schedules
  legitimately differ — coalescing changes decision points by design,
  see test_event_coalescing.py — so the golden is per-eps
  rerun-reproducibility plus cross-backend identity, never cross-eps.);
* **robustness** — every scheduler survives the all-knobs-hot model with
  paranoid index cross-checks enabled on every fault path and zero lost
  jobs: crash/recover, retry + backoff, blacklist + probation,
  speculative re-execution, and estimation-sample loss all fire.
"""

import pytest

from repro.core import FaultModel

from conformance import (
    DISCIPLINE_SCHEDULERS,
    TRACE_SCHEDULERS,
    assert_traces_equal,
    run_trace,
)

ALL_SCHEDULERS = TRACE_SCHEDULERS + DISCIPLINE_SCHEDULERS

#: Every fault class firing at once at quick-trace scale; the smoke
#: numbers (hundreds of task failures, dozens of crashes/blacklists,
#: speculation wins AND losses, sample losses) confirm each path is hot.
HOT = dict(
    seed=3,
    machine_mtbf=4000.0,
    machine_mttr=120.0,
    task_fail_rate=0.08,
    straggler_prob=0.1,
    straggler_factor=4.0,
    sample_loss_rate=0.3,
    blacklist_threshold=2,
    probation_s=100.0,
)


def hot_model(**over) -> FaultModel:
    return FaultModel(**{**HOT, **over})


def _backend_params():
    out = ["numpy"]
    try:
        import jax  # noqa: F401

        out.extend(["jax", "auto"])
    except Exception:
        out.extend(
            pytest.param(b, marks=pytest.mark.skip(reason="no jax"))
            for b in ("jax", "auto")
        )
    return out


# ---------------------------------------------------------------------------
# Inertness: disabled faults leave the executor bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_disabled_fault_model_is_bit_inert(name):
    """A default (all-rates-zero) FaultModel — and an explicitly seeded
    one — must not perturb a single bit of the schedule: same
    completions, stats, and pass counts as no model at all, and no
    fault block in the summary."""
    ref = run_trace(name, 0)
    assert "faults" not in ref
    for fm in (FaultModel(), FaultModel(seed=99)):
        assert not fm.enabled
        got = run_trace(name, 0, faults=fm)
        assert "faults" not in got
        assert_traces_equal(ref, got)


# ---------------------------------------------------------------------------
# Determinism goldens: same seed -> same failure trace + completions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("eps", (0.0, 0.5))
@pytest.mark.parametrize("name", ("hfsp", "hfsp-kill", "fifo", "psbs"))
def test_fault_trace_reproducible_at_each_epsilon(name, eps):
    """Golden: rerunning the same FaultModel seed reproduces the exact
    ordered failure trace and completion schedule — at eps=0 AND inside
    a coalescing window (faults must key their RNG off stable
    identities, never off pass timing)."""
    a = run_trace(name, 0, faults=hot_model(), event_epsilon=eps)
    b = run_trace(name, 0, faults=hot_model(), event_epsilon=eps)
    assert a["fault_trace_sha"] == b["fault_trace_sha"]
    assert_traces_equal(a, b)
    assert len(a["completion"]) == 30  # zero lost jobs


@pytest.mark.parametrize("backend", _backend_params())
def test_fault_trace_identical_across_backends(backend):
    """Golden: the numpy / jax / auto virtual-cluster backends see the
    identical failure trace and produce the identical schedule — fault
    decisions derive from (seed, stream, key), never from backend
    state."""
    ref = run_trace("hfsp", 0, faults=hot_model(), vc_backend="numpy")
    got = run_trace("hfsp", 0, faults=hot_model(), vc_backend=backend)
    assert got["fault_trace_sha"] == ref["fault_trace_sha"]
    assert_traces_equal(ref, got)


@pytest.mark.parametrize("seed", (3, 11))
def test_different_fault_seeds_diverge(seed):
    """Sanity on the golden's teeth: a different FaultModel seed yields a
    different failure trace (the sha comparison is not vacuous)."""
    a = run_trace("hfsp", 0, faults=hot_model(seed=seed))
    b = run_trace("hfsp", 0, faults=hot_model(seed=seed + 1))
    assert a["fault_trace_sha"] != b["fault_trace_sha"]


# ---------------------------------------------------------------------------
# Robustness: every scheduler survives the hot model, paranoid-clean
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_all_schedulers_survive_hot_faults_paranoid(name):
    """The all-knobs-hot model with paranoid demand-index cross-checks on
    every fault path: 30/30 jobs complete, every fault class fired, and
    speculation accounting balances (each launch resolves to exactly a
    win or a loss by end of run)."""
    got = run_trace(name, 0, faults=hot_model(), paranoid=True)
    assert len(got["completion"]) == 30
    f = got["faults"]
    assert f["machine_crashes"] > 0
    assert f["machine_recoveries"] > 0
    assert f["task_failures"] > 0
    assert f["retries"] > 0
    assert f["blacklists"] > 0
    assert f["sample_losses"] >= 0  # LAS/FIFO/FAIR never train
    assert f["stragglers"] > 0
    assert (
        f["speculative_wins"] + f["speculative_losses"]
        == f["speculative_launches"]
    )
    assert f["work_lost_s"] > 0.0


def test_sample_loss_exercises_training_path():
    """HFSP with heavy sample loss still finalizes every job's size
    estimate and completes the trace (lose_sample re-requests or shrinks
    the sample set, never stalls training)."""
    got = run_trace(
        "hfsp", 1,
        faults=FaultModel(seed=5, sample_loss_rate=0.5, task_fail_rate=0.02),
        paranoid=True,
    )
    assert len(got["completion"]) == 30
    assert got["faults"]["sample_losses"] > 0


def test_retry_budget_exhaustion_is_counted():
    """A tiny retry budget under a high failure rate trips
    retries_exhausted without losing jobs (the budget caps re-admission
    pushes, not the task's right to eventually run)."""
    got = run_trace(
        "fifo", 0,
        faults=FaultModel(seed=2, task_fail_rate=0.3, max_task_retries=1),
    )
    assert len(got["completion"]) == 30
    assert got["faults"]["retries_exhausted"] > 0
