"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)`` /
``ARCHS`` list all 10 assigned architectures."""

from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    input_specs,
    reduced,
    shape_applicable,
)

ARCHS = [
    "olmo_1b",
    "command_r_35b",
    "gemma2_2b",
    "starcoder2_3b",
    "llava_next_34b",
    "rwkv6_1b6",
    "granite_moe_3b",
    "llama4_scout_17b",
    "whisper_base",
    "zamba2_2b7",
]

# Accept both dashed public ids and module names.
_ALIASES = {
    "olmo-1b": "olmo_1b",
    "command-r-35b": "command_r_35b",
    "gemma2-2b": "gemma2_2b",
    "starcoder2-3b": "starcoder2_3b",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "whisper-base": "whisper_base",
    "zamba2-2.7b": "zamba2_2b7",
}


def _module(name: str):
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke",
    "input_specs",
    "reduced",
    "shape_applicable",
]
