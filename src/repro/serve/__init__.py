from repro.serve.engine import (
    BatchingQueue, greedy_generate, make_decode_step, make_prefill_step,
)

__all__ = ["BatchingQueue", "greedy_generate", "make_decode_step", "make_prefill_step"]
