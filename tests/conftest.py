import os

# Smoke tests and benchmarks must see the REAL device count (the dry-run
# alone forces 512 host devices, in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
