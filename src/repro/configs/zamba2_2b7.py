"""zamba2-2.7b [hybrid]: 54 mamba2 layers, d_model=2560, ssm_state=64,
with ONE shared attention+MLP block (32H kv=32, d_ff=10240) applied every
6 layers — Zamba's parameter-sharing design [arXiv:2411.15242; hf]."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    act="gelu_glu",
    norm="rmsnorm",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_period=6,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = reduced(CONFIG)
